"""Basic layers: norms, dense projections, embeddings (pure-function style).

Parameters are plain nested dicts of jnp arrays; every layer is
``init(key, ...) -> params`` + ``apply(params, x, ...) -> y``.  Compute dtype
is the activation dtype; norms accumulate in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    h = h * jax.lax.rsqrt(var + eps)
    return (h * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(params, x, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    h = h * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return h.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.float32):
    p = {"w": normal_init(key, (d_in, d_out), scale=d_in**-0.5, dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": normal_init(key, (vocab, d), scale=1.0, dtype=dtype)}


def embed(params, tokens):
    return params["table"][tokens]


def unembed(params, x):
    """Tied read-out: logits = x @ table^T."""
    return x @ params["table"].astype(x.dtype).T


def sinusoidal_positions(positions, d: int, dtype=jnp.float32):
    """Classic sin/cos absolute embedding (MusicGen-style backbone stub)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
