"""Rotary position embeddings, including partial-rotary (ChatGLM3's 2d-RoPE
applies rotation to half the head dimension; the other half is untouched).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0):
    """Inverse frequencies for the rotated part (head_dim must be even)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def rope_cos_sin(positions, head_dim: int, theta: float = 10000.0):
    """cos/sin tables at given positions. positions: (...,) int -> (..., hd/2)."""
    inv = rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin, rotary_dim: int | None = None):
    """Rotate the first ``rotary_dim`` dims of the head dimension.

    x: (..., S, head_dim); cos/sin: (S, rotary_dim/2) broadcastable.
    Pairs are (x[2i], x[2i+1]) -- interleaved convention.
    """
    hd = x.shape[-1]
    rd = rotary_dim if rotary_dim is not None else hd
    xr = x[..., :rd]
    x_pass = x[..., rd:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    cos = cos.astype(x.dtype)
    sin = sin.astype(x.dtype)
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    if rd == hd:
        return yr
    return jnp.concatenate([yr, x_pass], axis=-1)
