"""Architecture registry: ``--arch <id>`` -> (FULL, SMOKE) ModelConfigs.

All 10 assigned architectures (see DESIGN.md §4) plus the paper's own
workload configs (propagation instances) in ``propagation.py``.
"""
from __future__ import annotations

from importlib import import_module

from ..models.config import SHAPES, InputShape, ModelConfig, cell_supported, input_specs

_MODULES = {
    "granite-3-2b": "granite_3_2b",
    "granite-3-8b": "granite_3_8b",
    "qwen2-0.5b": "qwen2_0_5b",
    "chatglm3-6b": "chatglm3_6b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "musicgen-medium": "musicgen_medium",
    "mamba2-780m": "mamba2_780m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-1b": "internvl2_1b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_MODULES)}")
    mod = import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE if smoke else mod.FULL


def all_cells():
    """Every (arch, shape) pair with its supported/skip verdict."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_supported(cfg, shape)
            yield arch, shape.name, ok, why
