"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 -- RG-LRU + local attention, pattern (rec, rec, attn)
[arXiv:2402.19427; unverified].  38 layers = 12 full periods + 2-rec tail."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    local_window=2048,
    layer_pattern=("rec", "rec", "attn"),
    d_rnn=4096,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=4,            # one period + 1-layer tail
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    local_window=16,
    layer_pattern=("rec", "rec", "attn"),
    d_rnn=64,
    tie_embeddings=True,
    attn_chunk=16,
    dtype="float32",
)
