"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936
-- GQA, QKV bias [arXiv:2407.10671; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=56,          # 14-head-like ratio: 4 heads x 14
    n_heads=4,
    n_kv_heads=2,
    head_dim=14,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    attn_chunk=32,
    dtype="float32",
)
