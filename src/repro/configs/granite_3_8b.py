"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155
-- GQA [hf:ibm-granite/granite-3.0-2b-base family; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="granite-3-8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
    attn_chunk=32,
    dtype="float32",
)
