"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(expert)=1536
vocab=102400, MoE 160e top-6, MLA kv_lora=512, 2 shared experts, first layer
dense (d_ff=12288), q_lora=1536, qk nope/rope=128/64, v=128
[arXiv:2405.04434; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,            # dense (first) layer width
    vocab_size=102400,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    top_k=6,
    d_ff_expert=1536,
    n_shared=2,
    d_ff_shared=3072,      # 2 shared experts x 1536
    first_k_dense=1,
)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    attn_type="mla",
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    n_experts=8,
    top_k=2,
    d_ff_expert=32,
    n_shared=2,
    d_ff_shared=64,
    first_k_dense=1,
    attn_chunk=32,
    dtype="float32",
)
