"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
-- InternViT frontend (stub patch embeddings) + Qwen2-0.5B-family LM
[arXiv:2404.16821; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    frontend="vision",
    n_frontend_tokens=1024,
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke",
    family="vlm",
    num_layers=2,
    d_model=56,
    n_heads=4,
    n_kv_heads=2,
    head_dim=14,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    tie_embeddings=True,
    frontend="vision",
    n_frontend_tokens=8,
    attn_chunk=32,
    dtype="float32",
)
