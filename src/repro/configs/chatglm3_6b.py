"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024
-- 2d/partial RoPE (half the head dim rotated), GQA [arXiv:2406.12793; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    partial_rotary=0.5,
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="chatglm3-6b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    partial_rotary=0.5,
    attn_chunk=32,
    dtype="float32",
)
