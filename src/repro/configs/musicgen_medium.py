"""musicgen-medium [audio]: 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048 -- decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only (per task spec): the EnCodec/conditioning frontend is a stub
-- ``input_specs()`` provides precomputed conditioning frame embeddings.
Positional encoding is sinusoidal (as in MusicGen); the FFN is modeled with
the shared SwiGLU block (DESIGN.md records this substitution)."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    pos_type="sinusoidal",
    frontend="audio",
    n_frontend_tokens=512,
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    pos_type="sinusoidal",
    frontend="audio",
    n_frontend_tokens=8,
    attn_chunk=32,
    dtype="float32",
)
