"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768
vocab=151936, MoE 128e top-8, head_dim=128, no shared experts
[hf:Qwen/Qwen3-30B-A3B; hf]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=6144,             # unused (all layers MoE); kept for reference
    vocab_size=151936,
    rope_theta=1e6,
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
    n_shared=0,
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    rope_theta=1e6,
    n_experts=8,
    top_k=2,
    d_ff_expert=32,
    n_shared=0,
    attn_chunk=32,
    dtype="float32",
)
