"""mamba2-780m [ssm]: 48L d_model=1536 (attention-free) vocab=50280,
ssm_state=128 -- SSD state-space duality [arXiv:2405.21060; unverified]."""
from ..models.config import ModelConfig

FULL = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attn_type="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_p=64,
    ssm_groups=1,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=256,
    attn_type="none",
    ssm_state=16,
    ssm_expand=2,
    ssm_head_p=16,
    ssm_groups=1,
    ssm_chunk=8,
    tie_embeddings=True,
    dtype="float32",
)
