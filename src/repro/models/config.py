"""ModelConfig: one dataclass covering all 10 assigned architectures, plus
the input-shape registry (train_4k / prefill_32k / decode_32k / long_500k)
and ``input_specs()`` -- ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    attn_type: str = "gqa"       # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0  # fraction of head_dim rotated (chatglm3: 0.5)
    pos_type: str = "rope"       # rope | sinusoidal (musicgen backbone stub)
    local_window: Optional[int] = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared: int = 0
    d_ff_shared: int = 0
    first_k_dense: int = 0       # deepseek-v2: first layer(s) dense
    moe_aux_coef: float = 0.001
    capacity_factor: float = 1.25
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_p: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    # hybrid (recurrentgemma 1:2 pattern)
    layer_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn")
    d_rnn: int = 0
    # modality frontend stubs
    frontend: str = "none"       # none | vision | audio
    n_frontend_tokens: int = 0   # patch/frame embeddings injected at prefill
    # numerics / compute
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_chunk: int = 512        # blockwise attention tile
    remat: bool = True
    dtype: str = "bfloat16"
    # Lowering controls (dry-run probes; see roofline/analysis.py):
    scan_layers: bool = True     # False => python loop over layers (unrolled HLO)
    unroll_inner: bool = False   # unroll attention/SSD chunk loops in HLO
    # §Perf hillclimb levers (baseline keeps both off):
    causal_skip: bool = False    # triangular attention tile schedule
    seq_shard: bool = False      # Megatron-style sequence-parallel residual

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / hybrid-local-attn only.)"""
        if self.attn_type == "none":
            return True
        if self.layer_pattern and self.local_window is not None:
            return True
        return False

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_p

    def segments(self):
        """Homogeneous layer segments [(kind, count)] for scan-over-layers."""
        if self.layer_pattern:
            period = len(self.layer_pattern)
            full, rem = divmod(self.num_layers, period)
            segs = []
            if full:
                segs.append(("pattern", full))
            if rem:
                segs.append((f"pattern_tail{rem}", 1))
            return segs
        if self.attn_type == "none":
            return [("mamba2", self.num_layers)]
        if self.n_experts > 0:
            segs = []
            if self.first_k_dense:
                segs.append(("dense", self.first_k_dense))
            segs.append(("moe", self.num_layers - self.first_k_dense))
            return segs
        return [("dense", self.num_layers)]


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is (arch x shape) runnable? (long_500k needs sub-quadratic attention.)"""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 512k decode KV infeasible (DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct only -- never allocates)
# ---------------------------------------------------------------------------


def _cache_specs(cfg: ModelConfig, batch: int, s_max: int):
    """Pytree of ShapeDtypeStructs matching the decode cache layout
    (must mirror models.transformer.init_cache)."""
    sds = jax.ShapeDtypeStruct
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    segs = []
    for kind, count in cfg.segments():
        if kind == "mamba2":
            conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            segs.append(
                {
                    "conv": sds((count, batch, 3, conv_ch), act),
                    "h": sds(
                        (count, batch, cfg.ssm_heads, cfg.ssm_head_p, cfg.ssm_state),
                        jnp.float32,
                    ),
                }
            )
        elif kind.startswith("pattern"):
            n_sub = (
                len(cfg.layer_pattern)
                if kind == "pattern"
                else int(kind.replace("pattern_tail", ""))
            )
            sub = {}
            for i in range(n_sub):
                sk = cfg.layer_pattern[i]
                if sk == "rec":
                    sub[f"sub{i}"] = {
                        "h": sds((count, batch, cfg.d_rnn), jnp.float32),
                        "conv": sds((count, batch, 3, cfg.d_rnn), act),
                    }
                else:  # local attn, rolling window
                    w = min(cfg.local_window, s_max)
                    sub[f"sub{i}"] = {
                        "k": sds((count, batch, cfg.n_kv_heads, w, cfg.head_dim), act),
                        "v": sds((count, batch, cfg.n_kv_heads, w, cfg.head_dim), act),
                    }
            segs.append(sub)
        elif cfg.attn_type == "mla":
            segs.append(
                {
                    "c": sds((count, batch, s_max, cfg.kv_lora_rank), act),
                    "kr": sds((count, batch, s_max, cfg.qk_rope_dim), act),
                }
            )
        else:
            w = s_max if cfg.local_window is None else min(cfg.local_window, s_max)
            segs.append(
                {
                    "k": sds((count, batch, cfg.n_kv_heads, w, cfg.head_dim), act),
                    "v": sds((count, batch, cfg.n_kv_heads, w, cfg.head_dim), act),
                }
            )
    return segs


def input_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sds = jax.ShapeDtypeStruct
    b = shape.global_batch
    s = shape.seq_len
    act = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.kind == "train":
        specs = {
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
        if cfg.frontend != "none":
            nf = cfg.n_frontend_tokens or 1024
            specs["frontend_embeds"] = sds((b, nf, cfg.d_model), act)
            specs["tokens"] = sds((b, s - nf), jnp.int32)
            specs["labels"] = sds((b, s - nf), jnp.int32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((b, s), jnp.int32)}
        if cfg.frontend != "none":
            nf = cfg.n_frontend_tokens or 1024
            specs["frontend_embeds"] = sds((b, nf, cfg.d_model), act)
            specs["tokens"] = sds((b, s - nf), jnp.int32)
        return specs
    # decode: one new token against a cache of size seq_len
    return {
        "tokens": sds((b, 1), jnp.int32),
        "pos": sds((), jnp.int32),
        "cache": _cache_specs(cfg, b, s),
    }
