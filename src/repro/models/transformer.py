"""Composable decoder stack covering all assigned architectures.

Layers are grouped into homogeneous *segments* (ModelConfig.segments()) and
executed with ``lax.scan`` over stacked per-layer parameters -- this keeps
the HLO size (and hence 512-device SPMD compile time) independent of depth,
and gives a natural per-layer remat boundary.

Block kinds:
  dense     -- GQA attention + SwiGLU            (granite/qwen2/chatglm3/
                                                  musicgen/internvl2 LM)
  moe       -- attention (GQA or MLA) + MoE      (qwen3-moe, deepseek-v2)
  mamba2    -- Mamba-2 SSD block                 (mamba2-780m)
  pattern   -- RecurrentGemma period: each sub-layer is (RG-LRU | local
               attention) + SwiGLU, pattern e.g. ("rec","rec","attn")

Every block has three modes: train (full seq, no cache), prefill (full seq,
emit cache), decode (one token, consume+emit cache).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..nn import attention as attn_lib
from ..nn import mla as mla_lib
from ..nn import moe as moe_lib
from ..nn import rglru as rglru_lib
from ..nn import ssm as ssm_lib
from ..nn.ffn import swiglu, swiglu_init
from ..nn.layers import (
    dense,
    dense_init,
    embed,
    embedding_init,
    normal_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_positions,
    unembed,
)
from ..nn.rope import rope_cos_sin
from .config import ModelConfig

Params = Dict[str, Any]


def act_dtype(cfg: ModelConfig):
    if cfg.dtype == "bfloat16":
        return jnp.bfloat16
    if cfg.dtype == "float64":
        return jnp.float64  # layout-equivalence tests / precision studies
    return jnp.float32


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab padded to a multiple of 256 so embedding/lm_head shard evenly
    over any production mesh axis (MaxText-style padding; pad logits are
    ordinary learned params that never receive label mass)."""
    v = cfg.vocab_size
    return v if v % 256 == 0 else v + (256 - v % 256)


def _mla_cfg(cfg: ModelConfig) -> mla_lib.MLAConfig:
    return mla_lib.MLAConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        q_lora_rank=cfg.q_lora_rank,
        kv_lora_rank=cfg.kv_lora_rank,
        qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim,
        v_dim=cfg.v_head_dim,
    )


def _moe_cfg(cfg: ModelConfig) -> moe_lib.MoEConfig:
    return moe_lib.MoEConfig(
        n_experts=cfg.n_experts,
        top_k=cfg.top_k,
        d_model=cfg.d_model,
        d_ff_expert=cfg.d_ff_expert,
        n_shared=cfg.n_shared,
        d_ff_shared=cfg.d_ff_shared,
        capacity_factor=cfg.capacity_factor,
    )


def _mamba_cfg(cfg: ModelConfig) -> ssm_lib.Mamba2Config:
    return ssm_lib.Mamba2Config(
        d_model=cfg.d_model,
        d_inner=cfg.d_inner,
        n_heads=cfg.ssm_heads,
        head_p=cfg.ssm_head_p,
        n_groups=cfg.ssm_groups,
        d_state=cfg.ssm_state,
        chunk=cfg.ssm_chunk,
    )


def _rglru_cfg(cfg: ModelConfig) -> rglru_lib.RGLRUConfig:
    return rglru_lib.RGLRUConfig(d_model=cfg.d_model, d_rnn=cfg.d_rnn)


# ---------------------------------------------------------------------------
# GQA attention sub-block
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": dense_init(k1, d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(k2, d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(k3, d, hkv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(k4, h * hd, d, bias=False, dtype=dtype),
    }


def _rot(cfg: ModelConfig):
    rd = int(cfg.head_dim * cfg.partial_rotary)
    return rd - rd % 2


def gqa_apply(p, x, cfg: ModelConfig, mode, cos, sin, cache=None, pos=None,
              window=None, q_offset=0, shd=None):
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = dense(p["wk"], x).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = dense(p["wv"], x).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    rd = _rot(cfg)
    if cfg.pos_type == "rope" and rd > 0:
        from ..nn.rope import apply_rope

        q = apply_rope(q, cos, sin, rotary_dim=rd)
        k = apply_rope(k, cos, sin, rotary_dim=rd)
    if shd is not None and mode != "decode":
        k = shd.kv(k)
        v = shd.kv(v)

    if mode == "decode":
        kc, vc = attn_lib.cache_update(cache["k"], cache["v"], k, v, pos, window)
        y = attn_lib.decode_attention(q, kc, vc, pos, window=window)
        new_cache = {"k": kc, "v": vc}
    else:
        y = attn_lib.blockwise_attention(
            q, k, v, causal=True, window=window,
            chunk_q=min(cfg.attn_chunk, s), chunk_k=min(cfg.attn_chunk, s),
            q_offset=q_offset, unroll=cfg.unroll_inner,
            causal_skip=cfg.causal_skip,
        )
        new_cache = {"k": k, "v": v} if mode == "prefill" else None
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return dense(p["wo"], y), new_cache


# ---------------------------------------------------------------------------
# Block initializers
# ---------------------------------------------------------------------------


def block_init(kind: str, key, cfg: ModelConfig, dtype):
    keys = jax.random.split(key, 8)
    if kind == "dense":
        p = {"ln1": rmsnorm_init(cfg.d_model, dtype), "ln2": rmsnorm_init(cfg.d_model, dtype)}
        if cfg.attn_type == "mla":
            p["attn"] = mla_lib.mla_init(keys[0], _mla_cfg(cfg), dtype)
        else:
            p["attn"] = gqa_init(keys[0], cfg, dtype)
        p["mlp"] = swiglu_init(keys[1], cfg.d_model, cfg.d_ff, dtype)
        return p
    if kind == "moe":
        p = {"ln1": rmsnorm_init(cfg.d_model, dtype), "ln2": rmsnorm_init(cfg.d_model, dtype)}
        if cfg.attn_type == "mla":
            p["attn"] = mla_lib.mla_init(keys[0], _mla_cfg(cfg), dtype)
        else:
            p["attn"] = gqa_init(keys[0], cfg, dtype)
        p["moe"] = moe_lib.moe_init(keys[1], _moe_cfg(cfg), dtype)
        return p
    if kind == "mamba2":
        return {
            "ln1": rmsnorm_init(cfg.d_model, dtype),
            "mix": ssm_lib.mamba2_init(keys[0], _mamba_cfg(cfg), dtype),
        }
    if kind.startswith("pattern"):
        n_sub = (
            len(cfg.layer_pattern)
            if kind == "pattern"
            else int(kind.replace("pattern_tail", ""))
        )
        p = {}
        for i in range(n_sub):
            sk = cfg.layer_pattern[i]
            sub = {
                "ln1": rmsnorm_init(cfg.d_model, dtype),
                "ln2": rmsnorm_init(cfg.d_model, dtype),
                "mlp": swiglu_init(keys[2 * i + 1], cfg.d_model, cfg.d_ff, dtype),
            }
            if sk == "rec":
                sub["mix"] = rglru_lib.rglru_block_init(
                    keys[2 * i], _rglru_cfg(cfg), dtype
                )
            else:
                sub["mix"] = gqa_init(keys[2 * i], cfg, dtype)
            p[f"sub{i}"] = sub
        return p
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Block apply (one layer of a segment)
# ---------------------------------------------------------------------------


def block_apply(kind: str, p, x, cfg: ModelConfig, mode, cos, sin,
                cache=None, pos=None, q_offset=0, shd=None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in ("dense", "moe"):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.attn_type == "mla":
            if mode == "decode":
                y, new_attn_cache = mla_lib.mla_decode(
                    p["attn"], h, _mla_cfg(cfg), cos, sin,
                    (cache["c"], cache["kr"]), pos,
                )
                new_cache = {"c": new_attn_cache[0], "kr": new_attn_cache[1]}
            else:
                y, c_out = mla_lib.mla_attention(
                    p["attn"], h, _mla_cfg(cfg), cos, sin, chunk=cfg.attn_chunk,
                    unroll=cfg.unroll_inner, causal_skip=cfg.causal_skip,
                )
                new_cache = (
                    {"c": c_out[0], "kr": c_out[1]} if mode == "prefill" else None
                )
        else:
            y, new_cache = gqa_apply(
                p["attn"], h, cfg, mode, cos, sin, cache, pos,
                window=cfg.local_window, q_offset=q_offset, shd=shd,
            )
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind == "dense":
            x = x + swiglu(p["mlp"], h2)
        else:
            ym, aux = moe_lib.moe_apply(p["moe"], h2, _moe_cfg(cfg))
            x = x + ym
        return x, new_cache, aux

    if kind == "mamba2":
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        if mode == "decode":
            y, (conv, hs) = ssm_lib.mamba2_decode(
                p["mix"], h, _mamba_cfg(cfg), (cache["conv"], cache["h"])
            )
            new_cache = {"conv": conv, "h": hs}
        else:
            y, (conv, hs) = ssm_lib.mamba2_forward(
                p["mix"], h, _mamba_cfg(cfg), unroll=cfg.unroll_inner
            )
            new_cache = (
                {"conv": conv.astype(x.dtype), "h": hs} if mode == "prefill" else None
            )
        return x + y, new_cache, aux

    if kind.startswith("pattern"):
        n_sub = (
            len(cfg.layer_pattern)
            if kind == "pattern"
            else int(kind.replace("pattern_tail", ""))
        )
        new_cache = {}
        for i in range(n_sub):
            sk = cfg.layer_pattern[i]
            sub = p[f"sub{i}"]
            sub_cache = cache[f"sub{i}"] if cache is not None else None
            h = rmsnorm(sub["ln1"], x, cfg.norm_eps)
            if sk == "rec":
                if mode == "decode":
                    y, (hs, conv) = rglru_lib.rglru_block_decode(
                        sub["mix"], h, _rglru_cfg(cfg),
                        (sub_cache["h"], sub_cache["conv"]),
                    )
                    new_cache[f"sub{i}"] = {"h": hs, "conv": conv}
                else:
                    y, (hs, conv) = rglru_lib.rglru_block_forward(
                        sub["mix"], h, _rglru_cfg(cfg)
                    )
                    if mode == "prefill":
                        new_cache[f"sub{i}"] = {
                            "h": hs,
                            "conv": conv.astype(x.dtype),
                        }
            else:
                y, c_out = gqa_apply(
                    sub["mix"], h, cfg, mode, cos, sin, sub_cache, pos,
                    window=cfg.local_window, q_offset=q_offset, shd=shd,
                )
                if c_out is not None:
                    new_cache[f"sub{i}"] = c_out
            x = x + y
            h2 = rmsnorm(sub["ln2"], x, cfg.norm_eps)
            x = x + swiglu(sub["mlp"], h2)
        return x, (new_cache if mode != "train" else None), aux

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole model: init / forward / prefill / decode
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Params:
    # Params are fp32 masters; forward casts to the compute dtype at use
    # (standard JAX mixed precision -- optimizer state stays fp32).
    dtype = jnp.float32
    keys = jax.random.split(key, 4 + len(cfg.segments()))
    vpad = padded_vocab(cfg)
    params: Params = {
        "embed": embedding_init(keys[0], vpad, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": normal_init(keys[1], (cfg.d_model, vpad), cfg.d_model**-0.5, dtype)
        }
    segs = []
    for idx, (kind, count) in enumerate(cfg.segments()):
        layer_keys = jax.random.split(keys[3 + idx], count)
        seg = jax.vmap(lambda k: block_init(kind, k, cfg, dtype))(layer_keys)
        segs.append(seg)
    params["segments"] = segs
    return params


def _rope_tables(cfg: ModelConfig, positions):
    rd = _rot(cfg)
    if cfg.pos_type != "rope" or rd == 0:
        rope_dim = cfg.qk_rope_dim if cfg.attn_type == "mla" else 2
        return rope_cos_sin(positions, rope_dim, cfg.rope_theta)
    dim = cfg.qk_rope_dim if cfg.attn_type == "mla" else rd
    return rope_cos_sin(positions, dim, cfg.rope_theta)


def _embed_inputs(params, cfg: ModelConfig, tokens, frontend_embeds=None):
    x = embed(params["embed"], tokens).astype(act_dtype(cfg))
    if frontend_embeds is not None:
        # Modality stub: precomputed patch/frame embeddings prepended.
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x], axis=1)
    if cfg.pos_type == "sinusoidal":
        pos = jnp.arange(x.shape[1])
        x = x + sinusoidal_positions(pos, cfg.d_model, x.dtype)
    return x


def _run_segments(params, cfg: ModelConfig, x, mode, cos, sin,
                  cache=None, pos=None, shd=None):
    """Scan each homogeneous segment. Returns (x, new_caches, aux_total)."""
    aux_total = jnp.float32(0.0)
    new_caches = []
    constrain = shd.hidden if shd is not None else (lambda v: v)

    for idx, (kind, count) in enumerate(cfg.segments()):
        seg_params = params["segments"][idx]
        seg_cache = cache[idx] if cache is not None else None

        def one_layer(x, layer_params, layer_cache, kind=kind):
            x = constrain(x)
            return block_apply(
                kind, layer_params, x, cfg, mode, cos, sin, layer_cache, pos,
                shd=shd,
            )

        if mode == "train" and cfg.remat:
            one_layer = jax.checkpoint(
                one_layer, policy=jax.checkpoint_policies.nothing_saveable
            )

        if not cfg.scan_layers:
            # Unrolled python loop (dry-run probe lowerings; exact HLO costs).
            ncs = []
            for li in range(count):
                lp = jax.tree.map(lambda a: a[li], seg_params)
                lc = (
                    jax.tree.map(lambda a: a[li], seg_cache)
                    if seg_cache is not None
                    else None
                )
                x, nc, a = one_layer(x, lp, lc)
                aux_total = aux_total + a
                ncs.append(nc)
            new_caches.append(
                jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
                if mode != "train" and ncs[0] is not None
                else None
            )
            continue

        if mode == "train":
            def body(carry, lp):
                x, aux = carry
                x, _, a = one_layer(x, lp, None)
                return (x, aux + a), None

            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg_params)
            new_caches.append(None)
        else:
            def body(carry, xs):
                x, aux = carry
                lp, lc = xs
                x, nc, a = one_layer(x, lp, lc)
                return (x, aux + a), nc

            if seg_cache is None:
                # prefill: no incoming cache; scan emits it
                def body_pf(carry, lp):
                    x, aux = carry
                    x, nc, a = one_layer(x, lp, None)
                    return (x, aux + a), nc

                (x, aux_total), nc = jax.lax.scan(body_pf, (x, aux_total), seg_params)
            else:
                (x, aux_total), nc = jax.lax.scan(
                    body, (x, aux_total), (seg_params, seg_cache)
                )
            new_caches.append(nc)
    return x, new_caches, aux_total


def forward_train(params, cfg: ModelConfig, tokens, frontend_embeds=None, shd=None):
    """Full training forward -> logits (B, S, V)."""
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)
    if shd is not None:
        x = shd.hidden(x)
    positions = jnp.arange(x.shape[1])
    cos, sin = _rope_tables(cfg, positions)
    x, _, aux = _run_segments(params, cfg, x, "train", cos, sin, shd=shd)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (
        unembed(params["embed"], x)
        if cfg.tie_embeddings
        else dense(params["lm_head"], x)
    )
    if shd is not None:
        logits = shd.logits(logits)
    return logits, aux


def prefill(params, cfg: ModelConfig, tokens, frontend_embeds=None, shd=None):
    """Prefill -> (logits_last (B, 1, V), caches)."""
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)
    if shd is not None:
        x = shd.hidden(x)
    positions = jnp.arange(x.shape[1])
    cos, sin = _rope_tables(cfg, positions)
    x, caches, _ = _run_segments(params, cfg, x, "prefill", cos, sin, shd=shd)
    x = rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = (
        unembed(params["embed"], x)
        if cfg.tie_embeddings
        else dense(params["lm_head"], x)
    )
    return logits, caches


def decode_step(params, cfg: ModelConfig, tokens, cache, pos, shd=None):
    """One decode step. tokens: (B, 1); pos: scalar index being written."""
    x = embed(params["embed"], tokens).astype(act_dtype(cfg))
    if cfg.pos_type == "sinusoidal":
        x = x + sinusoidal_positions(
            jnp.full((1,), pos, dtype=jnp.int32), cfg.d_model, x.dtype
        )
    cos, sin = _rope_tables(cfg, jnp.arange(1) + pos)
    x, new_caches, _ = _run_segments(
        params, cfg, x, "decode", cos, sin, cache=cache, pos=pos, shd=shd
    )
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (
        unembed(params["embed"], x)
        if cfg.tie_embeddings
        else dense(params["lm_head"], x)
    )
    return logits, new_caches


def init_cache(cfg: ModelConfig, batch: int, s_max: int, dtype=None):
    """Zero-initialized decode cache (mirrors config._cache_specs)."""
    from .config import _cache_specs

    specs = _cache_specs(cfg, batch, s_max)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)


def param_count(cfg: ModelConfig) -> int:
    import math

    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if cfg.n_experts == 0:
        return total
    e, k = cfg.n_experts, cfg.top_k
    moe_layers = cfg.num_layers - cfg.first_k_dense
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    total_expert = moe_layers * e * per_expert
    active_expert = moe_layers * k * per_expert
    return total - total_expert + active_expert
