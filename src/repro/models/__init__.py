"""Model layer: composable decoder stack + per-arch configuration."""
from .config import SHAPES, InputShape, ModelConfig, cell_supported, input_specs
from .transformer import (
    init_params,
    forward_train,
    prefill,
    decode_step,
    init_cache,
    param_count,
    active_param_count,
)
