"""repro.obs: zero-sync observability for the propagation engines.

Three halves, one subsystem (docs/OBSERVABILITY.md):

* ``obs.telemetry`` -- the DEVICE half: a fixed-capacity
  :class:`TelemetryPlane` carried through every fixed-point while_loop
  (per-round progress ring, round/early-stop/infeasibility counters), read
  back only where the host already syncs.  Telemetry-on is bitwise
  identical to telemetry-off by construction.
* ``obs.trace`` -- the HOST half: a :class:`Tracer` of structured spans
  (service pump/admit/readback, per-ticket lifecycles, engine phase
  splits) exported as schema-pinned JSON-lines, with optional
  ``jax.profiler`` trace annotations.
* ``obs.metrics`` -- the AGGREGATION half: a :class:`MetricsRegistry`
  putting every ad-hoc source (LRU cache_info, compile counts, fill
  histograms, service counters) behind one pinned-schema ``snapshot()``,
  plus :func:`run_metadata` for attributable bench merges.

``obs.timing`` carries the shared fenced-timing utilities (block-until-
ready fencing, paired-trials median) the benches build their rows from.
"""
from .metrics import (
    SNAPSHOT_KEYS,
    SNAPSHOT_SCHEMA_VERSION,
    MetricsRegistry,
    default_registry,
    run_metadata,
)
from .telemetry import (
    DEFAULT_CAPACITY,
    TelemetryPlane,
    TelemetrySnapshot,
    device_plane,
    host_snapshot,
    record_round,
    reset_rows,
)
from .timing import (
    fence,
    median_of,
    median_ratio,
    paired_trials,
    time_fenced,
    time_phases,
)
from .trace import (
    NULL_TRACER,
    SPAN_KEYS,
    SPAN_SCHEMA_VERSION,
    NullTracer,
    Span,
    Tracer,
)

__all__ = [
    "DEFAULT_CAPACITY",
    "MetricsRegistry",
    "NULL_TRACER",
    "NullTracer",
    "SNAPSHOT_KEYS",
    "SNAPSHOT_SCHEMA_VERSION",
    "SPAN_KEYS",
    "SPAN_SCHEMA_VERSION",
    "Span",
    "TelemetryPlane",
    "TelemetrySnapshot",
    "Tracer",
    "default_registry",
    "device_plane",
    "fence",
    "host_snapshot",
    "median_of",
    "median_ratio",
    "paired_trials",
    "record_round",
    "reset_rows",
    "run_metadata",
    "time_fenced",
    "time_phases",
]
