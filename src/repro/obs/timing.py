"""Fenced timing utilities shared by the benches (and anything else).

JAX dispatch is asynchronous: a wall-clock around ``fn()`` times the
*enqueue* unless the result is fenced with ``block_until_ready``.  Every
bench in this repo needs the same three moves -- fence, best-of repeats,
paired trials with a median ratio -- and before this module each grew its
own copy (``bench_prop``'s phase helpers, ``precision``'s fp32/f64
pairing).  This is the one implementation both import.

Methodology (docs/BENCHMARKS.md): :func:`time_fenced` takes best-of-
``repeats`` after ``warmup`` unmeasured calls (minimum = least-noise
estimator for a deterministic workload); :func:`paired_trials` interleaves
variants A/B/A/B per trial so drift hits both sides equally, and
:func:`median_ratio` reduces the per-trial ratios by median -- robust to a
single noisy trial in a way mean-of-ratios is not.
"""
from __future__ import annotations

import statistics
import time

import jax


def fence(x):
    """Block until ``x`` (any pytree of device arrays) has materialized."""
    return jax.block_until_ready(x)


def time_fenced(fn, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-``repeats`` wall seconds of ``fn()``, fencing its result.

    ``fn`` needs no fencing of its own -- whatever it returns is passed to
    ``jax.block_until_ready`` inside the timed region, so asynchronous
    dispatch cannot leak work past the clock.
    """
    for _ in range(warmup):
        fence(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fence(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def paired_trials(fns, trials: int = 5, repeats: int = 3, warmup: int = 1):
    """Interleaved timing of variants: ``trials`` rows of per-variant seconds.

    ``fns`` is a sequence of zero-arg callables (each fenced via
    :func:`time_fenced`); each trial times them in order, so slow drift --
    thermal, frequency scaling, a neighbour process -- lands on every
    variant instead of biasing whichever ran last.  Returns a list of
    ``len(fns)``-tuples, one per trial.
    """
    fns = list(fns)
    for fn in fns:  # shared warmup: compiles outside every timed region
        for _ in range(warmup):
            fence(fn())
    return [
        tuple(time_fenced(fn, repeats=repeats, warmup=0) for fn in fns)
        for _ in range(trials)
    ]


def median_of(trials, idx: int) -> float:
    """Median across trials of variant ``idx``'s seconds."""
    return statistics.median(t[idx] for t in trials)


def median_ratio(trials, num: int = 0, den: int = 1) -> float:
    """Median across trials of the per-trial ratio ``t[num] / t[den]``."""
    return statistics.median(t[num] / t[den] for t in trials)


def time_phases(phases, repeats: int = 3, warmup: int = 1, tracer=None) -> dict:
    """Time named zero-arg callables: ``{name: microseconds}``.

    The partitioned bench's phase breakdown (copy/reduce/combine/merge)
    in one call: each phase is fenced and best-of timed independently.
    When a ``tracer`` (``obs.trace.Tracer``) is given, each phase's timed
    region is also emitted as a span named ``phase:<name>``, putting the
    engine's phase split on the same trace as the service spans.
    """
    out = {}
    for name, fn in dict(phases).items():
        if tracer is not None:
            with tracer.span(f"phase:{name}", repeats=repeats):
                t = time_fenced(fn, repeats=repeats, warmup=warmup)
        else:
            t = time_fenced(fn, repeats=repeats, warmup=warmup)
        out[name] = t * 1e6
    return out
