"""Host-side span tracing: structured timing for everything OUTSIDE the jit.

The device half of observability (``obs.telemetry``) rides the while_loop;
this module covers the host half -- the service's pump/admit/readback
cycle, per-ticket submit->admit->steps->retire lifecycles, and the
partitioned engine's copy/reduce/combine/merge phase timings (via
``obs.timing.time_phases``, which emits one span per phase and replaces
the bespoke fencing code the benches used to duplicate).

Spans are plain records with a pinned schema (:data:`SPAN_KEYS`), exported
as JSON-lines by :meth:`Tracer.export` -- one object per line, trivially
grep-able and loadable into pandas/Perfetto tooling.  ``annotate=True``
additionally wraps each ``span()`` region in a ``jax.profiler``
TraceAnnotation so the same names show up on the device timeline when a
profiler trace is being captured (see docs/OBSERVABILITY.md).

A :class:`NullTracer` stands in when tracing is off: every call is a
no-op, so instrumented hot paths pay one attribute lookup, not an if-tree.
"""
from __future__ import annotations

import contextlib
import dataclasses
import io
import itertools
import json
import threading
import time

#: Pinned span schema: every exported JSON line has exactly these keys.
SPAN_KEYS = frozenset(
    {"name", "span_id", "parent_id", "t_start", "t_end", "dur_ms", "thread", "attrs"}
)

#: Schema version stamped into exports (bump on any SPAN_KEYS change).
SPAN_SCHEMA_VERSION = 1


@dataclasses.dataclass
class Span:
    """One completed span: a named ``[t_start, t_end]`` interval + attrs."""

    name: str
    span_id: int
    parent_id: int | None
    t_start: float
    t_end: float
    thread: str
    attrs: dict

    def to_dict(self) -> dict:
        """The pinned-schema dict this span exports as (one JSON line)."""
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "dur_ms": (self.t_end - self.t_start) * 1e3,
            "thread": self.thread,
            "attrs": self.attrs,
        }


class Tracer:
    """Collects spans; thread-safe; nesting tracked per thread.

    ``span(name, **attrs)`` is the context-manager form (times the block,
    parents nested spans); ``record(name, t_start, t_end, **attrs)`` logs
    an interval whose endpoints were captured elsewhere -- the service uses
    it to emit one ``ticket`` span per request at retirement from the
    timestamps the ticket already carries, with zero tracing work on the
    submit path.
    """

    def __init__(self, annotate: bool = False, clock=time.perf_counter):
        self._spans: list[Span] = []
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._annotate = annotate
        self._clock = clock

    def _stack(self):
        if not hasattr(self._local, "stack"):
            self._local.stack = []
        return self._local.stack

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Time a ``with`` block as one span (nested spans get parented)."""
        sid = next(self._ids)
        stack = self._stack()
        parent = stack[-1] if stack else None
        stack.append(sid)
        ann = contextlib.nullcontext()
        if self._annotate:
            try:
                import jax

                ann = jax.profiler.TraceAnnotation(name)
            except Exception:
                pass
        t0 = self._clock()
        try:
            with ann:
                yield sid
        finally:
            t1 = self._clock()
            stack.pop()
            self._append(Span(name, sid, parent, t0, t1, _thread_name(), attrs))

    def record(
        self, name: str, t_start: float, t_end: float, parent_id=None, **attrs
    ) -> int:
        """Log a span from externally captured endpoints; returns its id."""
        sid = next(self._ids)
        if parent_id is None:
            stack = self._stack()
            parent_id = stack[-1] if stack else None
        self._append(Span(name, sid, parent_id, t_start, t_end, _thread_name(), attrs))
        return sid

    def _append(self, span: Span):
        with self._lock:
            self._spans.append(span)

    def spans(self) -> list[Span]:
        """Snapshot of the collected spans (copy; safe to iterate)."""
        with self._lock:
            return list(self._spans)

    def clear(self):
        """Drop every collected span (export first if you want them)."""
        with self._lock:
            self._spans.clear()

    def export(self, path=None) -> str:
        """Serialize spans as JSON-lines; write to ``path`` when given.

        Every line is one span dict with exactly :data:`SPAN_KEYS` keys.
        Returns the serialized text either way.
        """
        buf = io.StringIO()
        for s in self.spans():
            buf.write(json.dumps(s.to_dict(), default=str))
            buf.write("\n")
        text = buf.getvalue()
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


class NullTracer(Tracer):
    """Tracing disabled: same interface, every operation a no-op."""

    def __init__(self):
        super().__init__()
        self._null = contextlib.nullcontext(0)

    def span(self, name, **attrs):  # noqa: D102 -- inherited contract
        return self._null

    def record(self, name, t_start, t_end, parent_id=None, **attrs):  # noqa: D102
        return 0

    def _append(self, span):
        pass


#: Shared do-nothing tracer -- the default collaborator of instrumented code.
NULL_TRACER = NullTracer()


def _thread_name() -> str:
    return threading.current_thread().name
