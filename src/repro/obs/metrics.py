"""Unified metrics registry + run metadata for the bench trajectory.

Before this module every metrics source was ad hoc: kernel LRU
``cache_info()``, per-bucket engine ``compile_counts()``, packing
``batch_stats()`` fill histograms, service early-stop counters -- each with
its own accessor and no common envelope.  :class:`MetricsRegistry` puts
them behind one ``snapshot()`` with a pinned top-level schema
(:data:`SNAPSHOT_KEYS`), so ``PropagationService.stats()`` and the bench's
``obs`` row report through a single shape.

Sources are zero-arg callables registered by name; a failing source lands
in ``errors`` instead of taking the snapshot down -- observability must
never crash the thing it observes.

:func:`run_metadata` stamps every ``BENCH_prop.json`` merge with the
environment that produced it (git commit, timestamp, jax version, x64
flag, backend), turning the bench file from unversioned snapshots into an
attributable trajectory.
"""
from __future__ import annotations

import datetime
import subprocess
import threading

#: Pinned top-level snapshot schema.
SNAPSHOT_KEYS = frozenset({"schema_version", "sources", "errors"})

#: Schema version stamped into snapshots (bump on any SNAPSHOT_KEYS change).
SNAPSHOT_SCHEMA_VERSION = 1


class MetricsRegistry:
    """Named zero-arg metric sources behind one pinned-schema snapshot.

    ``register(name, fn)`` adds a source whose ``fn()`` returns any
    JSON-able value; ``snapshot()`` evaluates them all under the pinned
    envelope ``{schema_version, sources, errors}``.  Thread-safe: sources
    may be registered while another thread snapshots.
    """

    def __init__(self):
        self._sources: dict[str, object] = {}
        self._lock = threading.Lock()

    def register(self, name: str, fn, replace: bool = False):
        """Add source ``name`` -> ``fn()``; re-registering needs ``replace``."""
        with self._lock:
            if name in self._sources and not replace:
                raise ValueError(f"metrics source already registered: {name!r}")
            self._sources[name] = fn

    def unregister(self, name: str):
        """Remove a source (missing names are a no-op)."""
        with self._lock:
            self._sources.pop(name, None)

    def source_names(self) -> tuple:
        """Registered source names, sorted."""
        with self._lock:
            return tuple(sorted(self._sources))

    def snapshot(self) -> dict:
        """Evaluate every source: ``{schema_version, sources, errors}``.

        A source that raises contributes ``errors[name] = repr(exc)``
        rather than propagating -- one broken gauge never blinds the rest.
        """
        with self._lock:
            items = list(self._sources.items())
        sources, errors = {}, {}
        for name, fn in items:
            try:
                sources[name] = fn()
            except Exception as e:  # noqa: BLE001 -- isolation is the contract
                errors[name] = repr(e)
        return {
            "schema_version": SNAPSHOT_SCHEMA_VERSION,
            "sources": sources,
            "errors": errors,
        }


def default_registry() -> MetricsRegistry:
    """Registry preloaded with the process-wide sources every run has:
    kernel LRU ``cache_info()`` and the service engine cache.  Callers
    (the service constructor, the bench's ``obs`` row) add their own
    instance-scoped sources on top."""
    from ..kernels.ops import cache_info  # lazy: kernels pulls in jax state

    reg = MetricsRegistry()
    reg.register("kernel_caches", cache_info)
    return reg


def run_metadata() -> dict:
    """Environment fingerprint for a bench merge: commit, time, jax, backend.

    Never raises -- a missing git binary or detached worktree degrades the
    commit field to ``"unknown"`` so benches keep running anywhere.
    """
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5, check=True,
        ).stdout.strip() or "unknown"
    except Exception:
        commit = "unknown"
    try:
        import jax

        jax_version = jax.__version__
        x64 = bool(jax.config.jax_enable_x64)
        backend = jax.default_backend()
    except Exception:
        jax_version, x64, backend = "unknown", False, "unknown"
    return {
        "git_commit": commit,
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "jax_version": jax_version,
        "x64": x64,
        "backend": backend,
    }
