"""Device-resident telemetry for the zero-sync fixed-point loops.

The paper's central property -- propagation rounds run entirely on the
accelerator with no host synchronization -- is exactly what makes the
engines unobservable from the host: every per-round signal lives inside a
``jax.lax.while_loop`` dispatch.  The fix (Sofranac et al. arXiv:2106.07573;
Talbot et al. arXiv:2207.12116 do the same for on-device search statistics)
is to keep the statistics *on device too*: a fixed-capacity
:class:`TelemetryPlane` rides the loop carry, :func:`record_round` appends
one sample per round with pure array ops, and the host reads the plane back
only where it already syncs -- at fixed-point exit, or at the service's
retirement boundary.

Recording never touches the bound dataflow: the progress measure it stores
is already computed by every driver (it feeds the tier switch and the early
stop), and the infeasibility probe is a reduction over the same bound
planes the round just produced.  Telemetry-on therefore returns bitwise-
identical bounds to telemetry-off by construction -- asserted across all
four engines in ``tests/test_obs.py``.

The branch-and-bound solver (``core.solver.solve``) reuses the SCALAR
plane at search granularity -- one :func:`record_round` call per search
LEVEL instead of per propagation round: the ring sample is the next
frontier's open-node count, ``stop_round`` latches the first level that
improved the incumbent, and ``infeas_round`` the first level that fathomed
an infeasible node.  Same plane, same zero added syncs -- the whole search
trajectory rides the ``while_loop`` carry and is read back only at the
solver's ``sync_every`` boundary.

Plane layout (``capacity`` = ring size, per instance/slot when batched):

========================  =======================================================
field                     meaning
========================  =======================================================
``ring[..., capacity]``   per-round progress measure, ring buffer (NaN = unused)
``ticks[...]``            rounds recorded so far (keeps counting past capacity)
``stop_round[...]``       round the early stop tripped, ``-1`` if it never did
``infeas_round[...]``     first round the bounds crossed, ``-1`` if never
========================  =======================================================
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

#: Default ring capacity when a driver is asked for telemetry without an
#: explicit size.  Covers the default ``max_rounds=100`` tail behaviour
#: while keeping the loop-carry footprint trivial (256 B per instance).
DEFAULT_CAPACITY = 64


class TelemetryPlane(NamedTuple):
    """The device half of the telemetry: a pytree carried through while_loop.

    Scalar engines carry ``ring (cap,), ticks (), stop_round (),
    infeas_round ()``; batched engines and the service carry a leading
    ``(B,)`` axis on every field.  Being a NamedTuple it is a registered
    pytree, so it threads through ``jax.jit`` / ``lax.while_loop`` carries
    and buffer donation like any other state entry.
    """

    ring: jnp.ndarray
    ticks: jnp.ndarray
    stop_round: jnp.ndarray
    infeas_round: jnp.ndarray

    @property
    def capacity(self) -> int:
        """Ring size (static -- safe to read under trace)."""
        return int(self.ring.shape[-1])


def device_plane(capacity: int, batch: int | None = None, dtype=jnp.float32):
    """Fresh all-empty plane: NaN ring, zero ticks, ``-1`` event rounds.

    ``batch=None`` builds the scalar layout, an int the batched one.
    ``dtype`` is the ring's sample dtype -- drivers pass their bound dtype
    so stored progress is exactly the device scalar they computed.
    """
    shape = () if batch is None else (int(batch),)
    cap = int(capacity)
    return TelemetryPlane(
        ring=jnp.full(shape + (cap,), jnp.nan, dtype),
        ticks=jnp.zeros(shape, jnp.int32),
        stop_round=jnp.full(shape, -1, jnp.int32),
        infeas_round=jnp.full(shape, -1, jnp.int32),
    )


def record_round(
    plane: TelemetryPlane,
    progress,
    rounds,
    infeasible,
    stopped=None,
    active=None,
) -> TelemetryPlane:
    """Append one round's sample to the plane -- pure, while_loop-body safe.

    ``progress`` is the round's progress measure, ``rounds`` the 1-based
    round counter AFTER this round, ``infeasible`` the crossed-bounds
    predicate over the post-round planes, ``stopped`` the early-stop
    predicate (optional).  Batched callers pass ``active`` -- the
    per-instance mask of who actually executed this round -- so frozen
    instances' rings stay untouched and their ticks do not advance.

    At capacity the ring wraps (``ticks % capacity``): the plane keeps the
    LAST ``capacity`` samples, the interesting end of a converging
    trajectory.  ``stop_round`` / ``infeas_round`` latch the FIRST round
    their event fired and never move again.
    """
    cap = plane.capacity
    prog = jnp.asarray(progress).astype(plane.ring.dtype)
    rounds = jnp.asarray(rounds, jnp.int32)
    idx = plane.ticks % cap
    if active is None:
        ring = plane.ring.at[idx].set(prog)
        ticks = plane.ticks + 1
        infeas_round = jnp.where(
            (plane.infeas_round < 0) & infeasible, rounds, plane.infeas_round
        )
        stop_round = plane.stop_round
        if stopped is not None:
            stop_round = jnp.where((stop_round < 0) & stopped, rounds, stop_round)
    else:
        rows = jnp.arange(plane.ring.shape[0])
        ring = plane.ring.at[rows, idx].set(
            jnp.where(active, prog, plane.ring[rows, idx])
        )
        ticks = plane.ticks + active.astype(jnp.int32)
        infeas_round = jnp.where(
            (plane.infeas_round < 0) & infeasible & active,
            rounds,
            plane.infeas_round,
        )
        stop_round = plane.stop_round
        if stopped is not None:
            stop_round = jnp.where(
                (stop_round < 0) & stopped & active, rounds, stop_round
            )
    return TelemetryPlane(ring, ticks, stop_round, infeas_round)


def reset_rows(plane: TelemetryPlane, rows) -> TelemetryPlane:
    """Re-empty the given batch rows (the service's admission reset).

    ``rows`` is an integer index array; the named rows return to the fresh
    :func:`device_plane` state while every other row is untouched.  Pure --
    usable inside the service's jitted admit.
    """
    cap = plane.ring.shape[-1]
    k = rows.shape[0]
    return TelemetryPlane(
        ring=plane.ring.at[rows].set(jnp.full((k, cap), jnp.nan, plane.ring.dtype)),
        ticks=plane.ticks.at[rows].set(0),
        stop_round=plane.stop_round.at[rows].set(-1),
        infeas_round=plane.infeas_round.at[rows].set(-1),
    )


@dataclasses.dataclass
class TelemetrySnapshot:
    """Host-side handle on a plane, attached to ``PropagationResult.telemetry``.

    Deliberately lazy: fields hold whatever arrays the driver produced
    (device arrays at fixed-point exit, numpy after a service readback) and
    nothing forces a transfer until an accessor is called -- attaching a
    snapshot adds zero host syncs.  ``index`` selects one row of a batched
    plane so per-instance results of a batch share one underlying plane.

    ``tier_switch_round`` is the round the two-tier scheme promoted fp32 to
    the endgame dtype (``-1`` single-tier), stamped host-side at the same
    decision point that already reads ``r32.rounds``; ``fp32`` then holds
    the fp32 tier's own snapshot.
    """

    plane: TelemetryPlane
    index: int | None = None
    tier_switch_round: int = -1
    fp32: "TelemetrySnapshot | None" = None

    def _field(self, arr):
        a = np.asarray(arr)
        return a[self.index] if self.index is not None else a

    @property
    def capacity(self) -> int:
        """Ring size of the underlying plane."""
        return int(self.plane.ring.shape[-1])

    @property
    def rounds_recorded(self) -> int:
        """Total rounds the loop recorded (may exceed :attr:`capacity`)."""
        return int(self._field(self.plane.ticks))

    @property
    def stop_round(self) -> int:
        """Round the early stop tripped, ``-1`` if it never did."""
        return int(self._field(self.plane.stop_round))

    @property
    def infeasible_round(self) -> int:
        """First round the bounds crossed, ``-1`` if never."""
        return int(self._field(self.plane.infeas_round))

    def progress_history(self) -> np.ndarray:
        """Per-round progress, oldest-to-newest, unused tail trimmed.

        Length ``min(rounds_recorded, capacity)``; past capacity the ring
        wrapped, so this is the LAST ``capacity`` rounds in order.
        """
        ring = self._field(self.plane.ring)
        ticks = self.rounds_recorded
        cap = ring.shape[-1]
        if ticks <= cap:
            return ring[:ticks]
        head = ticks % cap
        return np.concatenate([ring[head:], ring[:head]])

    def summary(self) -> dict:
        """Plain-dict digest (the registry / bench row form)."""
        hist = self.progress_history()
        return {
            "capacity": self.capacity,
            "rounds_recorded": self.rounds_recorded,
            "stop_round": self.stop_round,
            "infeasible_round": self.infeasible_round,
            "last_progress": float(hist[-1]) if hist.size else float("nan"),
            "tier_switch_round": self.tier_switch_round,
        }


def host_snapshot(
    history,
    capacity: int,
    stop_round: int = -1,
    infeas_round: int = -1,
) -> TelemetrySnapshot:
    """Snapshot from host-recorded per-round progress (the host_loop drivers).

    Reproduces the device plane's exact semantics -- same ring layout, same
    wrap position -- from a Python list of per-round progress values, so a
    host_loop run's telemetry reads identically to a device_loop run's.
    """
    arr = np.asarray(history, np.float64)
    cap = int(capacity)
    ring = np.full(cap, np.nan, np.float64)
    k = int(arr.shape[0])
    if k and cap:
        keep = arr[-min(k, cap):]
        idx = np.arange(k - keep.shape[0], k) % cap
        ring[idx] = keep
    plane = TelemetryPlane(
        ring=ring,
        ticks=np.int32(k),
        stop_round=np.int32(stop_round),
        infeas_round=np.int32(infeas_round),
    )
    return TelemetrySnapshot(plane=plane)
