"""repro: TPU-native domain propagation at scale (Sofranac et al. 2020) +
the assigned-architecture LM substrate sharing the same distributed runtime.

IMPORTANT: this package must stay import-side-effect-free w.r.t. jax device
state -- launch/dryrun.py sets XLA_FLAGS before first jax init.
"""
