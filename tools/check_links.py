"""Docs gate: every relative link / file reference in the markdown docs
must resolve inside the repo (no network access in CI, so external http(s)
links are not fetched -- only flagged if malformed).

    python tools/check_links.py README.md docs/*.md
"""
from __future__ import annotations

import os
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_file(path: str) -> "list[str]":
    bad = []
    root = os.path.dirname(os.path.abspath(path))
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for target in LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        rel, _, frag = target.partition("#")
        if not rel:  # pure in-page anchor
            continue
        dest = os.path.normpath(os.path.join(root, rel))
        if not os.path.exists(dest):
            bad.append(f"{path}: broken link -> {target}")
            continue
        if frag and dest.endswith(".md"):
            with open(dest, encoding="utf-8") as g:
                heads = [
                    re.sub(r"[^\w\- ]", "", h.strip("# ").strip().lower()).replace(" ", "-")
                    for h in g.readlines()
                    if h.startswith("#")
                ]
            if frag.lower() not in heads:
                bad.append(f"{path}: broken anchor -> {target}")
    return bad


def main(argv: "list[str]") -> int:
    paths = argv or ["README.md"]
    bad = []
    for p in paths:
        bad += check_file(p)
    if bad:
        print("Broken markdown links:")
        for line in bad:
            print(f"  {line}")
        return 1
    print(f"link check OK ({len(paths)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
