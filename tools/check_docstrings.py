"""Docs gate: every public symbol of ``repro.core`` / ``repro.core.solver``
/ ``repro.kernels`` / ``repro.obs`` must carry a real docstring.

A "real" docstring excludes the auto-generated ``Name(field, ...)`` text
NamedTuples get for free.  Module-level constants (ints, floats, tuples)
are exempt -- they are documented where they are defined.  Run from the
repo root:

    PYTHONPATH=src python tools/check_docstrings.py
"""
from __future__ import annotations

import inspect
import sys


def missing_docstrings(mod) -> "list[str]":
    names = getattr(mod, "__all__", None) or [
        n for n in vars(mod) if not n.startswith("_")
    ]
    bad = []
    for name in names:
        obj = getattr(mod, name, None)
        if obj is None and name not in vars(mod):
            bad.append(f"{mod.__name__}.{name}: exported but missing")
            continue
        if not (inspect.isclass(obj) or callable(obj)):
            continue  # constants document themselves at the definition site
        if inspect.ismodule(obj):
            continue
        doc = inspect.getdoc(obj)
        if not doc or not doc.strip():
            bad.append(f"{mod.__name__}.{name}: no docstring")
            continue
        # NamedTuple auto-docstring: "Name(field1, field2, ...)".
        if inspect.isclass(obj) and doc.startswith(f"{obj.__name__}("):
            bad.append(f"{mod.__name__}.{name}: auto-generated docstring only")
    return bad


def main() -> int:
    import repro.core
    import repro.core.solver
    import repro.kernels
    import repro.obs

    bad = (
        missing_docstrings(repro.core)
        + missing_docstrings(repro.core.solver)
        + missing_docstrings(repro.kernels)
        + missing_docstrings(repro.obs)
    )
    if bad:
        print("Missing docstrings on exported symbols:")
        for line in bad:
            print(f"  {line}")
        return 1
    n = (
        len(getattr(repro.core, "__all__", []))
        + len([x for x in vars(repro.core.solver) if not x.startswith("_")])
        + len([x for x in vars(repro.kernels) if not x.startswith("_")])
        + len(getattr(repro.obs, "__all__", []))
    )
    print(f"docstring check OK ({n} exported symbols inspected)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
